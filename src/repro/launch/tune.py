"""Kernel tune-sweep CLI (DESIGN.md §3.11).

Runs the deterministic timed sweep over every kernel family's schedule
space at one (block, series-length) shape, prints the winning configs
and measured planner stage costs, and optionally writes the resulting
``TuneTable`` as JSON.  Every candidate schedule is checked
bit-identical against the reference before it may win, so the output
is a pure performance artifact — pasting a stale table never changes a
distance.

The checked-in per-backend defaults in
``repro/kernels/tuning/defaults.py`` were produced by this CLI; rerun
it and update that dict when the kernels change shape.  For a single
session, prefer ``Database.build(..., tune=True)`` — it runs the same
sweep and persists the table inside the ``.npz`` bundle.

Usage:
  python -m repro.launch.tune --length 128 --block 64
  python -m repro.launch.tune --families lb_fused,dtw --p inf \
      --iters 5 --out /tmp/tune.json
"""

from __future__ import annotations

import argparse
import json

from repro.kernels.tuning import SESSION_FAMILIES, autotune_session


def _parse_p(s: str):
    if s == "inf":
        import jax.numpy as jnp

        return jnp.inf
    return int(s)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--length", type=int, default=128,
                    help="series length n to tune at")
    ap.add_argument("--block", type=int, default=64,
                    help="candidate block size b to tune at")
    ap.add_argument("--window", type=int, default=None,
                    help="Sakoe-Chiba half-width (default: length // 10)")
    ap.add_argument("--p", default="1", help="distance power: 1, 2 or inf")
    ap.add_argument("--queries", type=int, default=4,
                    help="query-batch width for the qbatch families")
    ap.add_argument("--iters", type=int, default=3,
                    help="timing repetitions per candidate config")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--families", default="",
                    help="comma-separated subset (default: all of "
                    f"{', '.join(SESSION_FAMILIES)})")
    ap.add_argument("--no-costs", action="store_true",
                    help="skip the planner stage-cost measurement")
    ap.add_argument("--out", default="",
                    help="write the tuned TuneTable as JSON to this path")
    args = ap.parse_args(argv)

    families = (
        tuple(f for f in args.families.split(",") if f)
        or SESSION_FAMILIES
    )
    unknown = sorted(set(families) - set(SESSION_FAMILIES))
    if unknown:
        ap.error(f"unknown families {unknown}; known: {SESSION_FAMILIES}")

    table = autotune_session(
        n=args.length,
        b=args.block,
        w=args.window if args.window is not None else max(args.length // 10, 1),
        p=_parse_p(args.p),
        families=families,
        nq=args.queries,
        iters=args.iters,
        seed=args.seed,
        measure_costs=not args.no_costs,
        verbose=True,
    )

    print("\n# winners (paste-ready for kernels/tuning/defaults.py):")
    for (family, backend, bucket), cfg in sorted(table.entries.items()):
        print(f'    ("{family}", "{backend}", "{bucket}"): '
              f"KernelConfig(tile_b={cfg.tile_b}, lane_chunk={cfg.lane_chunk}, "
              f'depth={cfg.depth}, grid="{cfg.grid}"),')
    if table.stage_costs:
        print("# measured stage costs (sweep units, planner override):")
        for stage, cost in sorted(table.stage_costs.items()):
            print(f"#   {stage}: {cost:.3f}")

    if args.out:
        with open(args.out, "w") as f:
            f.write(table.to_json())
        print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
