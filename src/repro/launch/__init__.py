"""Launchers: mesh construction, dry-run, train/serve/search CLIs.

NOTE: import repro.launch.dryrun only as a __main__ entry point — it sets
XLA_FLAGS for 512 host devices at import time.
"""

from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
