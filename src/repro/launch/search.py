"""Distributed DTW search service launcher (the paper's system at scale).

Shards a time-series database across every device of the mesh and
serves nearest-neighbour queries through the two-pass LB_Improved
cascade with best-bound exchange (repro.core.distributed).

Usage:
  python -m repro.launch.search --db-size 4096 --length 512 --queries 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.distributed import pad_database, sharded_nn_search
from repro.data.synthetic import random_walks
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=4096)
    ap.add_argument("--length", type=int, default=512)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--w", type=int, default=0, help="0 = n/10")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--block", type=int, default=32)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    mesh = make_host_mesh()
    w = args.w or args.length // 10
    db = random_walks(rng, args.db_size, args.length)
    dbp, n_real = pad_database(db, mesh, block=args.block)
    print(
        f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
        f"db={n_real} series x {args.length} (padded {dbp.shape[0]}) w={w}"
    )
    for qi in range(args.queries):
        q = random_walks(rng, 1, args.length)[0]
        t0 = time.perf_counter()
        res = sharded_nn_search(
            q, dbp, mesh, w=w, k=args.k, block=args.block,
            sync_every=args.sync_every,
        )
        dt = time.perf_counter() - t0
        s = res.stats
        print(
            f"query {qi}: nn={res.index} dist={res.distance:.3f} "
            f"{dt*1e3:.1f} ms  pruned_lb1={s.lb1_pruned} pruned_lb2={s.lb2_pruned} "
            f"dtw={s.full_dtw} ({100*s.pruning_ratio:.1f}% pruned)"
        )


if __name__ == "__main__":
    main()
