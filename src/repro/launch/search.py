"""Distributed DTW search service launcher (the paper's system at scale).

Serves nearest-neighbour queries through one ``repro.api.Database``
session: artifacts (envelopes, powered norms, optionally the stage-0
triangle index) are built **once**, the planner picks the pipeline —
sharded over the host mesh by default, the 4-stage indexed cascade with
``--index`` — and the query queue drains through query-major
microbatches (DESIGN.md §3.4), every batch riding one sweep.

Persistence is first-class: ``--db-path x.npz`` saves/loads the whole
session bundle (data + envelopes + index + config), so a restarted
service skips every build step.  ``--index-path`` keeps the older
index-only store working.

Usage:
  python -m repro.launch.search --db-size 4096 --length 512 --queries 16 \
      --query-batch 8
  python -m repro.launch.search --index --p inf --n-refs 16 \
      --db-path /tmp/rw.session.npz
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.api import Database, SearchConfig
from repro.core.microbatch import drain_queries, iter_query_batches
from repro.data.synthetic import random_walks
from repro.launch.mesh import make_host_mesh

__all__ = ["drain_queries", "iter_query_batches", "main"]


def _parse_p(s: str):
    import jax.numpy as jnp

    if s.strip().lower() in ("inf", "infinity"):
        return jnp.inf
    v = float(s)
    if not np.isfinite(v) or v <= 0:
        raise ValueError(f"p must be a positive norm order or 'inf', got {s!r}")
    return int(v) if v == int(v) else v


def load_session(args) -> Database | None:
    """Load the serving session from ``--db-path`` if a bundle exists.

    A loaded bundle *is* the session — its data, config and artifacts
    win over the CLI flags (they are what the artifacts are valid for).
    Every flag the bundle overrides is warned about explicitly; ``--k``
    stays live because it is per-call-safe.
    """
    from repro.index.store import npz_path

    if not (args.db_path and os.path.exists(npz_path(args.db_path))):
        return None
    db = Database.load(args.db_path)
    print(f"loaded session bundle from {args.db_path}: {db!r}")
    config = SearchConfig(
        w=args.w, p=args.p, k=args.k, block=args.block, method=args.method
    )
    diffs = [
        f"--{f}: bundle={getattr(db.config, f)!r} flag={getattr(config, f)!r}"
        for f in ("w", "p", "block", "method", "znorm", "precision")
        if getattr(db.config, f) != getattr(config, f)
    ]
    if (db.n_rows, db.length) != (args.db_size, args.length):
        diffs.append(
            f"--db-size/--length: bundle holds {db.n_rows} x {db.length}, "
            f"flags describe {args.db_size} x {args.length} — serving the "
            f"bundle's data (queries are generated at its length)"
        )
    if args.index != (db.index is not None):
        diffs.append(
            f"--index: bundle={'has' if db.index else 'has no'} stage-0 "
            f"index, flag asked for {'one' if args.index else 'none'} — "
            f"the planner serves what the bundle has"
        )
    if args.anytime and db.anytime is None:
        diffs.append(
            "--anytime: bundle has no anytime tier — rebuild without "
            "--db-path (or delete the bundle) to add one"
        )
    if diffs:
        print(
            "warning: serving under the bundle's saved session; these "
            "CLI flags are ignored (rebuild without --db-path, or "
            "delete the bundle, to change them):\n  "
            + "\n  ".join(diffs)
        )
    return db


def build_session(args, db_data: np.ndarray) -> Database:
    """Build (and optionally persist) the serving session from the flags."""
    from repro.index import load_index, save_index
    from repro.index.store import npz_path

    config = SearchConfig(
        w=args.w, p=args.p, k=args.k, block=args.block, method=args.method
    )
    index: object = False
    if args.index:
        if args.index_path and os.path.exists(npz_path(args.index_path)):
            index = load_index(args.index_path)
            print(f"loaded index from {args.index_path} (R={index.n_refs})")
        else:
            index = True
    anytime: bool | dict = False
    if args.anytime:
        lengths = tuple(int(s) for s in args.anytime.split(","))
        anytime = {"lengths": lengths}
    t0 = time.perf_counter()
    db = Database.build(
        db_data,
        config,
        index=index,
        anytime=anytime,
        n_refs=args.n_refs,
        n_clusters=args.n_clusters or None,
        seed=args.seed,
    )
    dt = time.perf_counter() - t0
    print(f"built session in {dt:.2f}s: {db!r}")
    if args.index and index is True and args.index_path:
        print(f"saved index to {save_index(db.index, args.index_path)}")
    if args.db_path:
        print(f"saved session bundle to {db.save(args.db_path)}")
    return db


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=4096)
    ap.add_argument("--length", type=int, default=512)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument(
        "--query-batch",
        type=int,
        default=8,
        help="queries served per sweep (query-major microbatching, §3.4)",
    )
    ap.add_argument("--w", type=int, default=0, help="0 = n/10")
    ap.add_argument("--p", type=_parse_p, default=1, help="1, 2 or inf")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--block", type=int, default=32)
    ap.add_argument(
        "--method",
        type=str,
        default="lb_improved",
        help="stage pipeline (repro.core.pipeline.PIPELINES), or 'auto' "
        "to let the calibrated cascade planner order the bounds",
    )
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--index",
        action="store_true",
        help="serve through the stage-0 triangle index instead of the mesh scan",
    )
    ap.add_argument("--n-refs", type=int, default=16)
    ap.add_argument("--n-clusters", type=int, default=0, help="0 = n_refs")
    ap.add_argument(
        "--db-path",
        type=str,
        default="",
        help="load the whole session bundle (data+envelopes+index+config) "
        "from this .npz if present, else build and save it",
    )
    ap.add_argument(
        "--index-path",
        type=str,
        default="",
        help="legacy index-only store: load the index from this .npz if "
        "present, else build and save it",
    )
    ap.add_argument(
        "--anytime",
        type=str,
        default="",
        help="build the anytime subsequence tier at these comma-separated "
        "lengths (e.g. '64,128'); required for --mode anytime",
    )
    ap.add_argument(
        "--mode",
        type=str,
        default="exact",
        choices=("exact", "anytime"),
        help="'anytime' serves budgeted best-so-far answers with sound "
        "error bounds through the cluster tier (DESIGN.md §3.10)",
    )
    ap.add_argument(
        "--budget",
        type=int,
        default=0,
        help="anytime exploration budget in refined windows per query "
        "(0 = unlimited, which bit-matches exact)",
    )
    ap.add_argument(
        "--query-length",
        type=int,
        default=0,
        help="query length (0 = the session's series length); shorter "
        "lengths route through the anytime subsequence tier",
    )
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    db = load_session(args)
    if db is None:  # no bundle: synthesize and build (the cold path)
        db = build_session(args, random_walks(rng, args.db_size, args.length))
    # queries follow the *session's* series length (or --query-length,
    # which routes through the anytime subsequence tier), so a loaded
    # bundle of a different --length serves instead of crashing
    qlen = args.query_length or db.length
    queries = random_walks(rng, args.queries, qlen)
    budget = args.budget or None
    anytime_route = args.mode == "anytime" or (
        db.anytime is not None and qlen != db.length
    )
    # --queries 0 (config-printout smoke runs) must stay a graceful no-op
    batch = max(1, min(args.query_batch, args.queries))
    # route on what the session actually has (a loaded bundle may differ
    # from the flags — make_session warned about it above)
    indexed = db.index is not None
    if not (indexed or anytime_route):
        mesh = make_host_mesh()
        db.use_mesh(mesh, sync_every=args.sync_every)
        print(f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    print(
        f"db={db.n_rows} series x {db.length} w={db.w} p={db.p} "
        f"query_batch={batch}"
    )
    print(
        db.plan(
            batch, mode=args.mode, budget=budget, length=qlen
        ).explain()
    )

    def search_block(block_q):
        # k is per-call-safe; mode/budget route per call as well
        return db.search(block_q, k=args.k, mode=args.mode, budget=budget)

    t_all = time.perf_counter()
    for qi, res in enumerate(drain_queries(queries, search_block, batch)):
        s = res.stats
        if anytime_route:
            extra = (
                f"err<={res.error_bound:.3f} refined={s.refined}"
                f"/{s.n_windows} clusters={s.clusters_explored}"
                f"/{s.clusters_total} "
            )
        elif indexed:
            extra = (
                f"stage0={s.lb0_pruned} ({100*s.stage0_ratio:.1f}%) "
                f"clusters={s.clusters_pruned}/{s.clusters_total} "
            )
        else:
            extra = ""
        per_stage = " ".join(
            f"pruned_{name}={n}" for name, n in s.pruned_by.items()
        )
        print(
            f"query {qi}: nn={res.index} dist={res.distance:.3f} "
            f"{extra}"
            f"{per_stage + ' ' if per_stage else ''}"
            f"dtw={s.full_dtw} ({100*s.pruning_ratio:.1f}% pruned)"
        )
    dt = time.perf_counter() - t_all
    print(
        f"served {args.queries} queries in {dt*1e3:.1f} ms "
        f"({args.queries/dt:.1f} queries/sec at batch {batch})"
    )


if __name__ == "__main__":
    main()
