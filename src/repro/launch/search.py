"""Distributed DTW search service launcher (the paper's system at scale).

Shards a time-series database across every device of the mesh and
serves nearest-neighbour queries through the two-pass LB_Improved
cascade with best-bound exchange (repro.core.distributed).

Queries are served **query-major** (DESIGN.md §3.4): the launcher drains
its query queue in microbatches of ``--query-batch`` so one sweep over
the database (one jit trace, one envelope pass, one bound-exchange lane
per query) serves a whole block of queries instead of re-tracing the
scan per query.  The final ragged batch is padded to the batch size and
the pad results dropped, so nothing recompiles.

With ``--index`` the launcher instead builds (or loads) a
triangle-inequality reference index (repro.index) and serves query
batches through the four-stage ``nn_search_indexed`` cascade, printing
stage-0 pruning statistics next to the usual LB counters.

Usage:
  python -m repro.launch.search --db-size 4096 --length 512 --queries 16 \
      --query-batch 8
  python -m repro.launch.search --index --p inf --n-refs 16 \
      --index-path /tmp/rw.idx.npz
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core.distributed import pad_database, sharded_nn_search
from repro.core.microbatch import drain_queries, iter_query_batches
from repro.data.synthetic import random_walks
from repro.launch.mesh import make_host_mesh

__all__ = ["drain_queries", "iter_query_batches", "main"]


def _parse_p(s: str):
    import jax.numpy as jnp

    if s.strip().lower() in ("inf", "infinity"):
        return jnp.inf
    v = float(s)
    if not np.isfinite(v) or v <= 0:
        raise ValueError(f"p must be a positive norm order or 'inf', got {s!r}")
    return int(v) if v == int(v) else v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=4096)
    ap.add_argument("--length", type=int, default=512)
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument(
        "--query-batch",
        type=int,
        default=8,
        help="queries served per sweep (query-major microbatching, §3.4)",
    )
    ap.add_argument("--w", type=int, default=0, help="0 = n/10")
    ap.add_argument("--p", type=_parse_p, default=1, help="1, 2, ... or inf")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--block", type=int, default=32)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--index",
        action="store_true",
        help="serve through the stage-0 triangle index instead of the mesh scan",
    )
    ap.add_argument("--n-refs", type=int, default=16)
    ap.add_argument("--n-clusters", type=int, default=0, help="0 = n_refs")
    ap.add_argument(
        "--index-path",
        type=str,
        default="",
        help="load the index from this .npz if present, else build and save it",
    )
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    w = args.w or args.length // 10
    db = random_walks(rng, args.db_size, args.length)
    queries = random_walks(rng, args.queries, args.length)
    # --queries 0 (config-printout smoke runs) must stay a graceful no-op
    batch = max(1, min(args.query_batch, args.queries))

    if args.index:
        _serve_indexed(args, db, queries, batch, w)
        return

    mesh = make_host_mesh()
    dbp, n_real = pad_database(db, mesh, block=args.block)
    print(
        f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
        f"db={n_real} series x {args.length} (padded {dbp.shape[0]}) "
        f"w={w} query_batch={batch}"
    )

    def search_block(block_q):
        return sharded_nn_search(
            block_q, dbp, mesh, w=w, p=args.p, k=args.k, block=args.block,
            sync_every=args.sync_every,
        )

    t_all = time.perf_counter()
    for qi, res in enumerate(drain_queries(queries, search_block, batch)):
        s = res.stats
        print(
            f"query {qi}: nn={res.index} dist={res.distance:.3f} "
            f"pruned_lb1={s.lb1_pruned} pruned_lb2={s.lb2_pruned} "
            f"dtw={s.full_dtw} ({100*s.pruning_ratio:.1f}% pruned)"
        )
    dt = time.perf_counter() - t_all
    print(
        f"served {args.queries} queries in {dt*1e3:.1f} ms "
        f"({args.queries/dt:.1f} queries/sec at batch {batch})"
    )


def _serve_indexed(args, db, queries, batch, w):
    from repro.core.cascade import nn_search_indexed
    from repro.index import build_index, load_index, save_index
    from repro.index.store import npz_path

    index = None
    if args.index_path and os.path.exists(npz_path(args.index_path)):
        index = load_index(args.index_path)
        index.validate(db.shape[0], db.shape[1], w, args.p)
        index.validate_data(db)  # refuse a stale index over different data
        print(f"loaded index from {args.index_path} (R={index.n_refs})")
    if index is None:
        t0 = time.perf_counter()
        index = build_index(
            db,
            w=w,
            p=args.p,
            n_refs=args.n_refs,
            n_clusters=args.n_clusters or None,
            seed=args.seed,
        )
        dt = time.perf_counter() - t0
        print(
            f"built index: R={index.n_refs} C={index.n_clusters} "
            f"c_w={index.constant:.3g} in {dt:.2f}s"
        )
        if args.index_path:
            print(f"saved index to {save_index(index, args.index_path)}")

    print(
        f"db={db.shape[0]} series x {db.shape[1]} w={w} p={args.p} "
        f"query_batch={batch}"
    )

    def search_block(block_q):
        return nn_search_indexed(block_q, db, index, k=args.k, block=args.block)

    t_all = time.perf_counter()
    for qi, res in enumerate(drain_queries(queries, search_block, batch)):
        s = res.stats
        print(
            f"query {qi}: nn={res.index} dist={res.distance:.3f} "
            f"stage0={s.lb0_pruned} ({100*s.stage0_ratio:.1f}%) "
            f"clusters={s.clusters_pruned}/{s.clusters_total} "
            f"lb1={s.lb1_pruned} lb2={s.lb2_pruned} dtw={s.full_dtw} "
            f"({100*s.pruning_ratio:.1f}% pruned)"
        )
    dt = time.perf_counter() - t_all
    print(
        f"served {args.queries} queries in {dt*1e3:.1f} ms "
        f"({args.queries/dt:.1f} queries/sec at batch {batch})"
    )


if __name__ == "__main__":
    main()
