"""Streaming subsequence-matching service launcher (DESIGN.md §3.5).

Simulates the production shape of the stream subsystem: an unbounded
noisy signal with planted template occurrences arrives in chunks; a
``StreamMatcher`` — obtained from a ``repro.api.Database`` session
whose rows are the template bank, so template envelopes are built once
and shared across matchers — ingests each chunk (online envelopes +
windowed cascade, one batched sweep per window block serves every
template) and finalized matches are polled and printed as the stream
advances.

With ``--threshold 0`` (the default) each template's threshold is
calibrated from the head of the stream: half the median exact DTW
distance of the first windows — far below noise windows, far above
planted occurrences for the synthetic workload.

Usage:
  python -m repro.launch.stream --samples 20000 --length 128 --hop 4 --p 2 --znorm
  python -m repro.launch.stream --samples 8000 --length 64 --p inf --chunk 512
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _parse_p(s: str):
    import jax.numpy as jnp

    if s.strip().lower() in ("inf", "infinity"):
        return jnp.inf
    v = float(s)
    if not np.isfinite(v) or v <= 0:
        raise ValueError(f"p must be a positive norm order or 'inf', got {s!r}")
    return int(v) if v == int(v) else v


def calibrate_thresholds(
    templates: np.ndarray,
    head: np.ndarray,
    w: int,
    p,
    hop: int,
    znorm: bool,
    frac: float = 0.5,
    max_windows: int = 64,
) -> np.ndarray:
    """Per-template threshold = ``frac`` x median exact DTW distance of
    the stream-head windows (a cheap stand-in for a labelled calibration
    set)."""
    from repro.core.dtw import dtw_qbatch
    from repro.stream.state import prefix_sums, window_mean_std_from_prefix
    from repro.stream.subsequence import znorm_series, znorm_windows

    n = templates.shape[1]
    starts = np.arange(0, head.size - n + 1, hop)[:max_windows]
    if starts.size == 0:
        raise ValueError("stream head too short to calibrate thresholds")
    wins = np.stack([head[s : s + n] for s in starts])
    qs = templates
    if znorm:
        c1, c2 = prefix_sums(head)
        mean, std = window_mean_std_from_prefix(c1, c2, starts, n)
        wins = znorm_windows(wins, mean, std)
        qs = np.stack([znorm_series(t) for t in templates])
    d = np.asarray(dtw_qbatch(qs, wins, w, p))  # (Q, W) rooted
    return frac * np.median(d, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=20000, help="stream length")
    ap.add_argument("--length", type=int, default=128, help="template length")
    ap.add_argument("--chunk", type=int, default=1024, help="push chunk size")
    ap.add_argument("--hop", type=int, default=4, help="window stride")
    ap.add_argument("--block", type=int, default=64, help="windows per sweep")
    ap.add_argument("--w", type=int, default=0, help="0 = length/10")
    ap.add_argument("--p", type=_parse_p, default=2, help="1, 2 or inf")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.0,
        help="match threshold (rooted distance); 0 = auto-calibrate",
    )
    ap.add_argument("--znorm", action="store_true", help="per-window z-norm")
    ap.add_argument(
        "--method",
        choices=("lb_improved", "lb_keogh", "full"),
        default="lb_improved",
    )
    ap.add_argument(
        "--no-prefilter",
        action="store_true",
        help="disable the S0 stream-envelope prune",
    )
    ap.add_argument("--plants", type=int, default=0, help="0 = samples/2000")
    ap.add_argument("--noise", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.api import Database, SearchConfig
    from repro.data.synthetic import planted_stream, template_bank

    rng = np.random.default_rng(args.seed)
    n = args.length
    w = args.w or max(n // 10, 1)
    templates = template_bank(n, kinds=("sine", "gaussian"))
    n_plants = args.plants or max(args.samples // 2000, 1)
    stream, plants = planted_stream(
        rng, args.samples, templates, n_plants, noise_level=args.noise
    )

    if args.threshold > 0:
        thr = np.full(templates.shape[0], args.threshold)
    else:
        thr = calibrate_thresholds(
            templates, stream[: min(4096, args.samples)], w, args.p,
            args.hop, args.znorm,
        )
    print(
        f"stream={args.samples} samples, {len(plants)} planted occurrences; "
        f"templates={templates.shape[0]}x{n} w={w} p={args.p} "
        f"hop={args.hop} znorm={args.znorm} "
        f"thresholds={np.round(thr, 3).tolist()}"
    )

    # session facade: the template bank is the database, its envelopes
    # are build-once artifacts shared by every matcher the session mints
    session = Database.build(
        templates,
        SearchConfig(
            w=w,
            p=args.p,
            block=args.block,
            method=args.method,
            znorm=args.znorm,
        ),
    )
    matcher = session.stream(
        threshold=thr,
        hop=args.hop,
        prefilter=not args.no_prefilter,
    )
    t0 = time.perf_counter()
    for lo in range(0, args.samples, args.chunk):
        matcher.push(stream[lo : lo + args.chunk])
        for m in matcher.poll():
            print(
                f"  t={lo + args.chunk:>8d}  match template {m.tid} "
                f"@ {m.start} dist={m.dist:.3f}"
            )
    matcher.flush()
    for m in matcher.poll():
        print(f"  t=   flush  match template {m.tid} @ {m.start} dist={m.dist:.3f}")
    dt = time.perf_counter() - t0

    s = matcher.stats
    total = int(s.n_windows.sum())
    hits = matcher.matches()
    # a detection counts as recovering a plant when it lands within a
    # small fraction of the template length (the best-DTW window can sit
    # a few samples off the plant, especially under z-normalization)
    tol = max(args.hop, n // 16)
    recovered = sum(
        any(m.tid == tid and abs(m.start - pos) <= tol for m in hits)
        for tid, pos, _ in plants
    )
    print(
        f"{args.samples} samples in {dt*1e3:.1f} ms "
        f"({args.samples/dt:,.0f} samples/sec); "
        f"{matcher.windows_evaluated} windows x {s.n_templates} templates"
    )
    print(
        f"pruned before DTW: {100*s.pruned_before_dtw:.1f}% "
        f"(S0 env {int(s.env_pruned.sum())}, lb1 {int(s.lb1_pruned.sum())}, "
        f"lb2 {int(s.lb2_pruned.sum())}, dtw {int(s.full_dtw.sum())} "
        f"of {total} template-window lanes)"
    )
    print(
        f"matches={len(hits)} planted_recovered={recovered}/{len(plants)}"
    )


if __name__ == "__main__":
    main()
