"""Training launcher: small-scale runnable entry point.

On this CPU container it trains reduced/~100M-class configs end to end
(see examples/train_lm.py); on a real pod the same code path jits the
train step with the production mesh shardings from launch.dryrun.

Usage:
  python -m repro.launch.train --arch granite-3-2b --reduced --steps 200
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import SyntheticTokenPipeline
from repro.models.model_zoo import build_model
from repro.optim import OptimizerConfig, optimizer_init, warmup_cosine
from repro.train import Trainer, TrainerConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    parallel = ParallelConfig(remat="none", compute_dtype="float32")
    model = build_model(cfg, parallel)
    print(f"{cfg.name}: {model.n_params:,} params")

    opt_cfg = OptimizerConfig(kind="adamw", lr=args.lr)
    sched = warmup_cosine(args.lr, warmup=max(args.steps // 20, 1), total=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, parallel, sched))

    pipeline = SyntheticTokenPipeline(
        cfg.vocab_size, args.seq, args.batch, seed=args.seed
    )

    def wrapped_step(params, opt_state, batch, step):
        if cfg.family == "vlm":
            b = batch["tokens"].shape[0]
            batch = dict(batch)
            batch["vision_embeds"] = jnp.zeros(
                (b, cfg.vision_tokens, cfg.d_model), jnp.float32
            )
            batch["labels"] = jnp.concatenate(
                [jnp.full((b, cfg.vision_tokens), -1, jnp.int32), batch["labels"]],
                axis=1,
            )
        if cfg.family == "audio":
            b = batch["tokens"].shape[0]
            batch = dict(batch)
            batch["frames"] = jnp.zeros(
                (b, cfg.encoder_len, cfg.d_model), jnp.float32
            )
        return step_fn(params, opt_state, batch, step)

    trainer = Trainer(
        wrapped_step,
        pipeline,
        TrainerConfig(
            total_steps=args.steps,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
        ),
        init_params=lambda: model.init(jax.random.PRNGKey(args.seed)),
        init_opt_state=lambda p: optimizer_init(opt_cfg, p),
    )
    out = trainer.run()
    print(
        json.dumps(
            {
                "final_step": out["final_step"],
                "final_loss": out["final_loss"],
                "mean_step_time": out["mean_step_time"],
            }
        )
    )


if __name__ == "__main__":
    main()
