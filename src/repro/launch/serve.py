"""Multi-tenant serving launcher: QueryEngine over one Database session.

Replays a mixed workload — exact repeats (answer-cache targets),
near-duplicate retrieval queries, and cold scans — from several
concurrent client threads through the async engine (DESIGN.md §3.8:
admission -> coalesce -> plan -> cache), optionally with a streaming
session running alongside, and reports sustained qps, p50/p99 latency
and the engine counters.  Every answer is verified bit-identical to a
direct ``db.search`` call before the numbers are printed.

Usage:
  python -m repro.launch.serve --db-size 2048 --length 256 --queries 64 \
      --clients 4 --max-batch 8 --max-wait-ms 2 --cache 128
  python -m repro.launch.serve --index --p inf --stream-samples 4096
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.api import Database, SearchConfig
from repro.data.synthetic import random_walks
from repro.serve import QueryEngine


def _parse_p(s: str):
    if s.strip().lower() in ("inf", "infinity"):
        return float("inf")
    v = float(s)
    return int(v) if v in (1.0, 2.0) else v


def mixed_workload(
    rng: np.random.Generator,
    db_data: np.ndarray,
    n_queries: int,
    *,
    repeat_frac: float = 0.3,
    near_frac: float = 0.4,
    pool: int = 8,
) -> np.ndarray:
    """The serving traffic mix: ``repeat_frac`` exact repeats drawn from
    a small pool (cache/coalesce targets), ``near_frac`` near-duplicates
    of database rows (the paper's retrieval regime), remainder cold
    random walks — shuffled into one replay order."""
    n, length = db_data.shape
    n_rep = int(n_queries * repeat_frac)
    n_near = int(n_queries * near_frac)
    n_cold = n_queries - n_rep - n_near
    pool_q = db_data[rng.integers(0, n, pool)] + rng.normal(
        scale=0.25, size=(pool, length)
    ).astype(db_data.dtype)
    rep = pool_q[rng.integers(0, pool, n_rep)]
    near = db_data[rng.integers(0, n, n_near)] + rng.normal(
        scale=0.25, size=(n_near, length)
    ).astype(db_data.dtype)
    cold = random_walks(rng, max(n_cold, 1), length)[:n_cold]
    work = np.concatenate([rep, near, cold], axis=0)
    return work[rng.permutation(len(work))]


def replay(
    engine: QueryEngine,
    workload: np.ndarray,
    n_clients: int,
    *,
    deadline: float | None = None,
) -> list[tuple[int, float, object]]:
    """Drive the workload through ``n_clients`` tenant threads (each a
    tenant name, open-loop: submit everything, then collect).  Returns
    ``(workload_index, latency_s, answer)`` triples."""
    shards = [list(range(c, len(workload), n_clients)) for c in range(n_clients)]
    out: list[tuple[int, float, object]] = []
    lock = threading.Lock()

    def client(cid: int):
        t_sub = {}
        futures = []
        for qi in shards[cid]:
            t_sub[qi] = time.perf_counter()
            futures.append(
                (qi, engine.submit(workload[qi], tenant=f"client{cid}",
                                   deadline=deadline))
            )
        for qi, fut in futures:
            ans = fut.result()
            with lock:
                out.append((qi, time.perf_counter() - t_sub[qi], ans))

    threads = [
        threading.Thread(target=client, args=(c,)) for c in range(n_clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=2048)
    ap.add_argument("--length", type=int, default=256)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-queue", type=int, default=4096)
    ap.add_argument("--cache", type=int, default=128, help="answer-cache entries")
    ap.add_argument("--w", type=int, default=0, help="0 = n/10")
    ap.add_argument("--p", type=_parse_p, default=1, help="1, 2 or inf")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--index", action="store_true",
                    help="build the stage-0 triangle index into the session")
    ap.add_argument("--n-refs", type=int, default=8)
    ap.add_argument("--repeat-frac", type=float, default=0.3)
    ap.add_argument("--near-frac", type=float, default=0.4)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request latency budget; 0 = none")
    ap.add_argument("--stream-samples", type=int, default=0,
                    help="also run a streaming session over this many samples")
    ap.add_argument("--stream-threshold", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    data = random_walks(rng, args.db_size, args.length)
    cfg = SearchConfig(w=args.w, p=args.p, k=args.k, block=args.block)
    t0 = time.perf_counter()
    db = Database.build(data, cfg, index=args.index, n_refs=args.n_refs,
                        seed=args.seed)
    print(f"built session in {time.perf_counter() - t0:.2f}s: {db!r}")

    workload = mixed_workload(
        rng, data, args.queries,
        repeat_frac=args.repeat_frac, near_frac=args.near_frac,
    )
    engine = QueryEngine(
        db,
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
        max_queue=args.max_queue,
        cache_capacity=args.cache,
    )
    print(db.plan(args.max_batch).explain())

    # one warmup wave compiles the (max_batch, n) specialisation so the
    # replayed numbers are serving, not tracing
    replay(engine, workload[: args.max_batch], 1)

    deadline = args.deadline_ms / 1e3 if args.deadline_ms > 0 else None
    t0 = time.perf_counter()
    served = replay(engine, workload, args.clients, deadline=deadline)
    wall = time.perf_counter() - t0

    # engine answers must be the direct session answers, bit for bit
    direct = db.search(workload)
    for qi, _, ans in served:
        assert np.array_equal(ans.distances, direct.distances[qi]), qi
        assert np.array_equal(ans.indices, direct.indices[qi]), qi

    lat_ms = np.sort([1e3 * dt for _, dt, _ in served])
    s = engine.stats()
    print(
        f"replayed {len(served)} queries from {args.clients} clients in "
        f"{wall * 1e3:.1f} ms: {len(served) / wall:.1f} qps sustained"
    )
    print(
        f"latency p50={np.percentile(lat_ms, 50):.2f} ms "
        f"p99={np.percentile(lat_ms, 99):.2f} ms max={lat_ms[-1]:.2f} ms"
    )
    print(
        f"engine: batches={s.batches} occupancy={s.batch_occupancy:.2f} "
        f"coalesced={s.coalesced} cache_hits={s.cache_hits} "
        f"(hit_rate={s.cache_hit_rate:.2f}) expired={s.expired} "
        f"wait_mean={s.wait_ms_mean:.2f} ms"
    )
    print("answers verified bit-identical to direct db.search")

    if args.stream_samples > 0:
        sess = engine.open_stream(threshold=args.stream_threshold)
        signal = random_walks(rng, 1, args.stream_samples)[0]
        t0 = time.perf_counter()
        hits = []
        for lo in range(0, signal.size, 512):
            hits += sess.feed(signal[lo : lo + 512])
        hits += sess.close()
        dt = time.perf_counter() - t0
        print(
            f"stream session: {signal.size} samples in {dt * 1e3:.1f} ms "
            f"({signal.size / dt:.0f} samples/sec), {len(hits)} matches, "
            f"windows={sess.matcher.windows_evaluated}"
        )

    engine.close()


if __name__ == "__main__":
    main()
