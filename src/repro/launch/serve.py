"""Serving launcher: batched greedy decoding with a reduced config.

Usage:
  python -m repro.launch.serve --arch granite-3-2b --batch 4 --new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ParallelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.models.model_zoo import build_model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg, ParallelConfig(remat="none", compute_dtype="float32"))
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_len=args.prompt_len + args.new + 1)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(
        np.int32
    )
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: generated {out.shape} in {dt:.2f}s")
    print(out[:, :8])


if __name__ == "__main__":
    main()
