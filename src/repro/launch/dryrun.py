import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or
2x16x16 multi-pod), constructs the model at FULL size (params as
ShapeDtypeStructs — nothing is allocated), applies the per-cell
parallelism policy, jits the appropriate step function with explicit
NamedShardings, and runs ``.lower().compile()``.  Success proves the
sharding configuration is coherent; the compiled artifact yields

  * ``memory_analysis()``  — per-device bytes (the "fits" proof),
  * ``cost_analysis()``    — HLO FLOPs / bytes for §Roofline,
  * optimized HLO text     — collective traffic via launch.hlo_analysis.

Artifacts land in benchmarks/artifacts/<cell>.json; benchmarks/roofline.py
turns them into the EXPERIMENTS.md tables.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig, ShapeConfig
from repro.configs.registry import (
    ARCH_IDS,
    all_cells,
    cell_is_runnable,
    get_config,
    get_shape,
)
from repro.distributed.sharding import ShardingRules, fit_tree, make_rules, use_rules
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.launch.policy import apply_overrides, optimizer_for_cell, parallel_for_cell
from repro.models.common import _nest
from repro.models.model_zoo import Model, batch_specs, build_model
from repro.optim import OptimizerConfig, optimizer_init
from repro.models.lm_serve import make_serve_step
from repro.train.train_step import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/artifacts")


def rules_for(model: Model, mesh, parallel: ParallelConfig) -> ShardingRules:
    cfg = model.cfg
    n_kv = cfg.n_kv_heads
    if cfg.family == "hybrid":
        n_kv = cfg.hybrid.shared_n_kv
    return make_rules(
        mesh,
        n_kv_heads=n_kv,
        n_heads=cfg.n_heads,
        n_experts=cfg.moe.n_experts if cfg.moe else 0,
        seq_shard=parallel.seq_shard_activations,
        shard_kv_cache_seq=parallel.shard_kv_cache_seq,
        fsdp=parallel.fsdp,
        tensor_parallel=parallel.tensor_parallel,
    )


def param_shardings(model: Model, rules: ShardingRules):
    return jax.tree.map(
        lambda axes: rules.sharding(axes),
        model.param_axes,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def opt_state_shardings(
    opt_cfg: OptimizerConfig, model: Model, rules: ShardingRules
):
    if opt_cfg.kind == "adamw":
        ps = param_shardings(model, rules)
        return {"m": ps, "v": ps}
    flat = {}
    for path, spec in model.specs.items():
        axes = spec.axes
        if len(spec.shape) >= 2 and min(spec.shape[-2:]) >= opt_cfg.min_dim_size_to_factor:
            flat[path] = {
                "vr": rules.sharding(axes[:-1]),
                "vc": rules.sharding(axes[:-2] + axes[-1:]),
                "m": rules.sharding(axes),
            }
        else:
            flat[path] = {"v": rules.sharding(axes), "m": rules.sharding(axes)}
    return _nest(flat)


def batch_shardings(model: Model, shape: ShapeConfig, rules: ShardingRules):
    def act(*axes):
        return rules.sharding(axes)

    if shape.kind == "train":
        sh = {
            "tokens": act("act_batch", "act_none"),
            "labels": act("act_batch", "act_none"),
        }
        if model.cfg.family == "vlm":
            sh["vision_embeds"] = act("act_batch", "act_none", "act_embed")
        if model.cfg.family == "audio":
            sh["frames"] = act("act_batch", "act_none", "act_embed")
        return sh
    if shape.kind == "prefill":
        sh = {"tokens": act("act_batch", "act_none")}
        if model.cfg.family == "vlm":
            sh["vision_embeds"] = act("act_batch", "act_none", "act_embed")
        if model.cfg.family == "audio":
            sh["frames"] = act("act_batch", "act_none", "act_embed")
        return sh
    cache_sh = jax.tree.map(
        lambda axes: rules.sharding(axes),
        model.cache_axes(),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )
    return {
        "tokens": act("act_batch", "act_none"),
        "pos": NamedSharding(rules.mesh, P()),
        "cache": cache_sh,
    }


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    n_params: int = 0
    compile_sec: float = 0.0
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: int = 0
    collective_by_kind: dict | None = None
    memory: dict | None = None
    policy: dict | None = None
    error: str = ""


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    overrides: dict | None = None,
    save_hlo: bool = False,
) -> CellResult:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return CellResult(arch, shape_name, mesh_kind, ok=True, skipped=True, reason=why)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    sizes = mesh_axis_sizes(mesh)
    data_shards = sizes.get("data", 1) * sizes.get("pod", 1)

    probe = build_model(cfg)  # for param count only (specs are cheap)
    parallel = parallel_for_cell(cfg, shape, probe.n_params, data_shards)
    if overrides:
        parallel = apply_overrides(parallel, overrides)
    model = build_model(cfg, parallel)
    rules = rules_for(model, mesh, parallel)

    pdtype = jnp.dtype(parallel.param_dtype)
    params_abs = model.abstract_params(pdtype)
    p_shard = fit_tree(param_shardings(model, rules), params_abs)
    b_specs = batch_specs(model, shape)
    b_shard = fit_tree(batch_shardings(model, shape, rules), b_specs)

    t0 = time.perf_counter()
    with use_rules(rules):
        if shape.kind == "train":
            opt_cfg = optimizer_for_cell(cfg, parallel, probe.n_params)
            opt_abs = jax.eval_shape(
                lambda p: optimizer_init(opt_cfg, p), params_abs
            )
            o_shard = fit_tree(opt_state_shardings(opt_cfg, model, rules), opt_abs)
            step_fn = make_train_step(model, opt_cfg, parallel)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard, NamedSharding(mesh, P())),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(
                params_abs, opt_abs, b_specs, jax.ShapeDtypeStruct((), jnp.int32)
            )
        elif shape.kind == "prefill":
            def prefill(params, batch):
                return model.prefill_step(params, batch)

            jitted = jax.jit(prefill, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_abs, b_specs)
        else:  # decode
            serve = make_serve_step(model)
            cache_sh = b_shard["cache"]
            jitted = jax.jit(
                serve,
                in_shardings=(
                    p_shard,
                    cache_sh,
                    b_shard["tokens"],
                    b_shard["pos"],
                ),
                out_shardings=(b_shard["tokens"], cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_abs, b_specs["cache"], b_specs["tokens"], b_specs["pos"]
            )
        compiled = lowered.compile()
    compile_sec = time.perf_counter() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax wraps the dict in a list
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        memory = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        } if mem is not None else None
    except Exception as e:  # CPU backend may not implement it
        memory = {"error": str(e)}

    hlo = compiled.as_text()
    coll = analyze_hlo(hlo)
    if save_hlo:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        with open(
            os.path.join(ARTIFACT_DIR, f"{arch}__{shape_name}__{mesh_kind}.hlo"), "w"
        ) as f:
            f.write(hlo)

    print(f"[{arch} x {shape_name} x {mesh_kind}] compiled in {compile_sec:.1f}s")
    print(f"  memory_analysis: {memory}")
    print(
        f"  cost_analysis(unweighted): flops={cost.get('flops', 0):.3e} "
        f"bytes={cost.get('bytes accessed', 0):.3e}"
    )
    print(
        f"  hlo walk (loop-weighted, per device): dot_flops={coll['dot_flops']:.3e} "
        f"hbm_bytes~={coll['hbm_bytes']:.3e}"
    )
    print(
        f"  collectives: total={coll['collective_bytes']:.3e} by_kind="
        f"{ {k: f'{v:.2e}' for k, v in coll['by_kind'].items()} } "
        f"warnings={len(coll['warnings'])}"
    )

    return CellResult(
        arch=arch,
        shape=shape_name,
        mesh=mesh_kind,
        ok=True,
        n_params=probe.n_params,
        compile_sec=compile_sec,
        flops=float(coll["dot_flops"]),
        bytes_accessed=float(coll["hbm_bytes"]),
        collective_bytes=coll["collective_bytes"],
        collective_by_kind=coll["by_kind"],
        memory=memory,
        policy=dataclasses.asdict(parallel),
    )


def save_result(res: CellResult, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{res.arch}__{res.shape}__{res.mesh}.json")
    with open(path, "w") as f:
        json.dump(dataclasses.asdict(res), f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument(
        "--override", action="append", default=[], help="key=value ParallelConfig override"
    )
    args = ap.parse_args()

    overrides = {}
    for item in args.override:
        k, v = item.split("=", 1)
        overrides[k] = int(v) if v.lstrip("-").isdigit() else (
            v if v in ("none", "full", "dots", "float32", "bfloat16", "adamw", "adafactor")
            else v == "true"
        )

    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    cells = (
        [(a, s) for a, s, _, _ in all_cells(include_skipped=True)]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = []
    for arch, shape_name in cells:
        for mesh_kind in meshes:
            try:
                res = run_cell(arch, shape_name, mesh_kind, overrides, args.save_hlo)
            except Exception as e:
                traceback.print_exc()
                res = CellResult(
                    arch, shape_name, mesh_kind, ok=False, error=f"{type(e).__name__}: {e}"
                )
                failures.append((arch, shape_name, mesh_kind))
            save_result(res, args.out)
    if failures:
        print(f"FAILED cells: {failures}")
        raise SystemExit(1)
    print("dry-run: all requested cells compiled")


if __name__ == "__main__":
    main()
