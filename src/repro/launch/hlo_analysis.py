"""Roofline-term extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (no trip-count
weighting) and has no collective term, so we do our own weighted walk of
the computation call graph:

* **collective bytes** — all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute output-shape bytes (standard on-wire
  proxy; ring constants noted in EXPERIMENTS.md §Roofline);
* **dot FLOPs** — 2 x output_elems x contracted_size per dot, operand
  shapes resolved through a per-computation symbol table (elementwise
  FLOPs excluded: matmuls dominate every cell here);
* **HBM byte proxy** — 2x the output bytes of every materialising
  instruction (post-fusion outputs ~ real buffer writes; x2 for the
  read side).  Fusion interiors are not double counted.

Loop weighting: a ``while`` body/condition is multiplied by the trip
count recovered from the largest integer constant in its condition
computation (XLA's canonical counted-loop form); missing counts fall
back to 1 and are recorded in ``warnings``.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_NO_BYTES_OPS = {
    "parameter",
    "constant",
    "get-tuple-element",
    "tuple",
    "bitcast",
    "iota",
    "after-all",
    "partition-id",
    "replica-id",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w]+\[[\d,]*\]\S*))\s+([\w\-]+)\((.*)$"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')


def _shape_info(text: str):
    """[(dtype, [dims]), ...] for every typed literal in the text."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _shape_info(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> tuple[dict[str, list[str]], str | None]:
    """Computations start at column 0 ('%name (...) -> ... {' / 'ENTRY ...');
    instructions are indented.  Returns (computations, entry_name)."""
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        if not line:
            continue
        if line[0] not in " \t":
            cur = None
            if line.rstrip().endswith("{") and "->" in line:
                head = line.lstrip()
                is_entry = head.startswith("ENTRY")
                if is_entry:
                    head = head[len("ENTRY") :].lstrip()
                name = re.split(r"[\s(]", head.lstrip("%"), maxsplit=1)[0]
                if name:
                    cur = name
                    comps[cur] = []
                    if is_entry:
                        entry = name
            continue
        stripped = line.strip()
        if cur is not None and stripped and stripped != "}":
            comps[cur].append(stripped)
    return comps, entry


class _Comp:
    def __init__(self):
        self.shapes: dict[str, str] = {}  # instr name -> result shape text
        self.coll = defaultdict(int)
        self.bytes = 0
        self.flops = 0
        self.whiles: list[tuple[str, str, int]] = []  # (body, condition, trip)
        self.calls: list[str] = []  # fusion/call/map/reduce to_apply etc.
        self.cond_consts: list[int] = []


_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply)=%?([\w.\-]+)|branch_computations=\{([^}]*)\}"
)


def _parse(hlo: str) -> tuple[dict[str, _Comp], str | None]:
    comps, entry = _split_computations(hlo)
    parsed: dict[str, _Comp] = {}
    for name, lines in comps.items():
        c = _Comp()
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            iname, shape_txt, op, rest = m.groups()
            c.shapes[iname] = shape_txt
            base = op.replace("-start", "")
            if base.endswith("-done"):
                continue
            if base in COLLECTIVES:
                c.coll[base] += _shape_bytes(shape_txt)
            if op not in _NO_BYTES_OPS:
                c.bytes += _shape_bytes(shape_txt)
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", rest)
                cm = re.search(r"condition=%?([\w.\-]+)", rest)
                tm = _TRIP_RE.search(rest)
                if bm:
                    c.whiles.append(
                        (
                            bm.group(1),
                            cm.group(1) if cm else "",
                            int(tm.group(1)) if tm else 0,
                        )
                    )
            elif op == "dot":
                c.flops += _dot_flops(shape_txt, rest, c.shapes)
            else:
                for cm2 in _CALL_ATTR_RE.finditer(rest):
                    target = cm2.group(1) or cm2.group(2) or ""
                    for callee in re.split(r"[,\s%]+", target):
                        if callee:
                            c.calls.append(callee)
            c.cond_consts.extend(int(x) for x in _CONST_RE.findall(rest))
            if op == "constant":
                val = rest.split(")")[0].strip()
                if val.isdigit():
                    c.cond_consts.append(int(val))
        parsed[name] = c
    return parsed, entry


def _dot_flops(out_shape: str, rest: str, symbols: dict[str, str]) -> int:
    out = _shape_info(out_shape)
    if not out:
        return 0
    out_elems = 1
    for d in out[0][1]:
        out_elems *= d
    # operand 0 name
    ops = rest.split(")")[0]
    names = [t.strip().lstrip("%") for t in ops.split(",")]
    lhs_shape = symbols.get(names[0]) if names else None
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    contracted = 1
    if lhs_shape and cm:
        dims = _shape_info(lhs_shape)
        if dims:
            lhs_dims = dims[0][1]
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contracted *= lhs_dims[int(idx)]
    return 2 * out_elems * contracted


def analyze_hlo(hlo: str) -> dict:
    parsed, entry = _parse(hlo)
    warnings: list[str] = []

    if entry is None:
        called = set()
        for c in parsed.values():
            called.update(b for b, _, _ in c.whiles)
            called.update(cond for _, cond, _ in c.whiles)
            called.update(c.calls)
        entries = [n for n in parsed if n not in called]
        entry = entries[-1] if entries else next(iter(parsed), None)

    def trip(cond_name: str, known: int) -> int:
        if known:
            return known
        c = parsed.get(cond_name)
        if not c or not c.cond_consts:
            warnings.append(f"no trip count for {cond_name}; assuming 1")
            return 1
        return max(c.cond_consts)

    memo: dict[str, tuple] = {}

    def walk(name: str, depth=0):
        """-> (coll_bykind, bytes, flops) with loop weighting."""
        if name in memo:
            return memo[name]
        c = parsed.get(name)
        if c is None or depth > 64:
            return ({}, 0, 0)
        coll = defaultdict(int, c.coll)
        total_bytes = c.bytes
        flops = c.flops
        for callee in c.calls:
            sub_coll, sub_b, sub_f = walk(callee, depth + 1)
            for k, v in sub_coll.items():
                coll[k] += v
            flops += sub_f  # interior bytes intentionally not added
        for body, cond, known in c.whiles:
            t = trip(cond, known)
            sub_coll, sub_b, sub_f = walk(body, depth + 1)
            for k, v in sub_coll.items():
                coll[k] += v * t
            total_bytes += sub_b * t
            flops += sub_f * t
        memo[name] = (dict(coll), total_bytes, flops)
        return memo[name]

    coll, bytes_out, flops = walk(entry) if entry else ({}, 0, 0)
    return {
        "collective_bytes": int(sum(coll.values())),
        "by_kind": {k: int(v) for k, v in coll.items()},
        "dot_flops": int(flops),
        "hbm_bytes": int(2 * bytes_out),
        "warnings": warnings,
        "entry": entry,
    }


def analyze_collectives(hlo: str) -> dict:
    """Backwards-compatible wrapper."""
    return analyze_hlo(hlo)
